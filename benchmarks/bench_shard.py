"""Fleet-axis sharding suite — device-count scaling of the client
dimension (DESIGN.md §11).

Two axes, both run in child interpreters because fabricated host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=D``) must be set
before the first jax import:

* **device axis** (measured) — the SAME sharded fedpairing driver run
  (vmapped engine, fixed N) on 1/2/4/8 fabricated devices, per-round
  wall-clock after the compile round.  Fabricated CPU devices share the
  host's cores, so wall-clock on a small host is an honest *overhead*
  measurement, not a speedup claim; ``host_cores`` is recorded so the
  reader can tell which regime a number came from.
* **N sweep** (compile-only) — the vmapped fed step AOT-lowered at
  growing client counts (to 10k) with the client axis sharded over 1 vs
  8 devices; XLA's per-device memory analysis and flop count show the
  per-device footprint dropping ~D-fold, which is the resource that
  actually scales on a real multi-chip mesh.

Writes machine-readable ``BENCH_shard.json`` at the repo root
(``tiny=True`` smoke runs write ``BENCH_shard_tiny.json``); schema in
``benchmarks/README.md``:

    {"host_cores": .., "backend": ..,
     "fixed_n": {"clients": .., "rounds": .., "batches_per_round": ..,
                 "devices": {"<D>": {"mean_round_wall_s": ..,
                                     "round_wall_s": [..],
                                     "overhead_vs_1dev": ..}}},
     "n_sweep": {"<N>": {"<D>": {"arg_bytes_per_device": ..,
                                 "temp_bytes_per_device": ..,
                                 "out_bytes_per_device": ..,
                                 "flops": ..},
                         "arg_shrink_8dev": ..}}}
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_shard.json")
# tiny (smoke/CI) runs write elsewhere so they never clobber the tracked
# per-PR perf record with shrunken-config numbers
TINY_JSON_PATH = os.path.join(_ROOT, "BENCH_shard_tiny.json")

DEVICE_COUNTS = (1, 2, 4, 8)
TINY_DEVICE_COUNTS = (1, 2)
SWEEP_N = (1000, 4000, 10000)
TINY_SWEEP_N = (8, 16)
SWEEP_DEVICES = (1, 8)
TINY_SWEEP_DEVICES = (1, 2)

# runs in a child interpreter: argv[1] is a JSON config
# {"devices": D, "clients": N, "rounds": R, "batches_per_round": B,
#  "batch": b, "seq": S, "sweep_n": [..]}; the last stdout line is
# "RESULT <json>"
CHILD_CODE = r"""
import json, os, sys, time
cfg_in = json.loads(sys.argv[1])
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + str(cfg_in["devices"]))
import jax
import jax.numpy as jnp
from repro import compat
from repro.configs import get_smoke_config
from repro.core import fedpair, latency, rounds, splitting
from repro.core.latency import ChannelModel
from repro.models import registry
from repro.sharding.fleet import make_fleet_sharding

d = cfg_in["devices"]
assert jax.device_count() == d
cfg = get_smoke_config("tinyllama-1.1b")
sh = make_fleet_sharding()
out = {"devices": d}

# -- measured: sharded driver rounds at fixed N ---------------------------
n = cfg_in["clients"]
rc = rounds.RoundConfig(
    algorithm="fedpairing", engine="vmapped",
    rounds=cfg_in["rounds"], batches_per_round=cfg_in["batches_per_round"],
    drift_sigma_m=2.0, seed=0)
fleet = latency.make_fleet(n=n, seed=0)
driver = rounds.RoundDriver(
    cfg, rc, fleet, chan=ChannelModel(),
    batch_fn=rounds.make_lm_batch_fn(cfg, n, cfg_in["batch"],
                                     cfg_in["seq"], 0),
    sharding=sh)
state = driver.init_state()
walls = []
for i in range(rc.rounds):
    t0 = time.perf_counter()
    state = driver.run_round(state)
    walls.append(time.perf_counter() - t0)
# round 0 pays the compile; report the steady-state rounds
out["round_wall_s"] = [round(w, 4) for w in walls[1:]]
out["compile_round_s"] = round(walls[0], 4)
leaf = jax.tree_util.tree_leaves(state.client_params)[0]
out["param_leaf_devices"] = len(leaf.sharding.device_set)

# -- compile-only: per-device footprint of the fed step at sweep Ns -------
gparams = registry.init_params(cfg, jax.random.key(0))
plan = splitting.split_plan(cfg, gparams)
step = fedpair.make_fed_step(
    lambda p, b: registry.loss_fn(p, b, cfg)[0], plan, cfg.num_layers,
    fedpair.FedPairingConfig(donate=False))
out["sweep"] = {}
for sweep_n in cfg_in["sweep_n"]:
    params_s = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(
            (sweep_n,) + a.shape, a.dtype,
            sharding=sh.client_sharding(
                jax.ShapeDtypeStruct((sweep_n,) + a.shape, a.dtype))),
        gparams)
    tok = jax.ShapeDtypeStruct(
        (sweep_n, cfg_in["batch"], cfg_in["seq"]), jnp.int32)
    batch_s = {"tokens": jax.ShapeDtypeStruct(tok.shape, tok.dtype,
                                              sharding=sh.client_sharding(tok)),
               "labels": jax.ShapeDtypeStruct(tok.shape, tok.dtype,
                                              sharding=sh.client_sharding(tok))}
    ivec = jax.ShapeDtypeStruct((sweep_n,), jnp.int32)
    fvec = jax.ShapeDtypeStruct((sweep_n,), jnp.float32)
    compiled = step.lower(params_s, batch_s, ivec, ivec, fvec).compile()
    mem = compiled.memory_analysis()
    out["sweep"][str(sweep_n)] = {
        "arg_bytes_per_device": int(mem.argument_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "out_bytes_per_device": int(mem.output_size_in_bytes),
        "flops": float(compat.cost_analysis(compiled).get("flops", 0.0)),
    }
print("RESULT " + json.dumps(out))
"""


def _child(config: Dict) -> Dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)          # the child sets its own
    res = subprocess.run(
        [sys.executable, "-c", CHILD_CODE, json.dumps(config)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=3600)
    for line in reversed(res.stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(
        f"shard bench child (devices={config['devices']}) produced no "
        f"result:\n{res.stdout[-2000:]}\n{res.stderr[-4000:]}")


def run(tiny: bool = False, json_path: str = "") -> List[Dict]:
    json_path = json_path or (TINY_JSON_PATH if tiny else JSON_PATH)
    device_counts = TINY_DEVICE_COUNTS if tiny else DEVICE_COUNTS
    sweep_n = TINY_SWEEP_N if tiny else SWEEP_N
    sweep_devices = TINY_SWEEP_DEVICES if tiny else SWEEP_DEVICES
    n = 4 if tiny else 16
    rounds_n = 2 if tiny else 3
    batches = 1 if tiny else 2
    batch, seq = (1, 16) if tiny else (2, 32)

    rows: List[Dict] = []
    fixed: Dict[str, Dict] = {}
    sweep: Dict[str, Dict] = {str(sn): {} for sn in sweep_n}
    for d in device_counts:
        child = _child({
            "devices": d, "clients": n, "rounds": rounds_n,
            "batches_per_round": batches, "batch": batch, "seq": seq,
            # the N sweep only needs its two endpoints' device counts
            "sweep_n": list(sweep_n) if d in sweep_devices else [],
        })
        assert child["param_leaf_devices"] == d, child
        walls = child["round_wall_s"]
        mean_wall = sum(walls) / len(walls)
        fixed[str(d)] = {"mean_round_wall_s": round(mean_wall, 4),
                         "round_wall_s": walls,
                         "compile_round_s": child["compile_round_s"]}
        for sn, entry in child["sweep"].items():
            sweep[sn][str(d)] = entry
        rows.append({
            "name": f"shard/fixedN{n}/dev{d}",
            "us_per_call": mean_wall * 1e6,
            "derived": f"rounds={len(walls)} "
                       f"compile_s={child['compile_round_s']:.2f}",
        })

    base = fixed[str(device_counts[0])]["mean_round_wall_s"]
    for d in device_counts:
        fixed[str(d)]["overhead_vs_1dev"] = round(
            fixed[str(d)]["mean_round_wall_s"] / base, 3)

    d_lo, d_hi = str(sweep_devices[0]), str(sweep_devices[-1])
    for sn in sweep:
        lo, hi = sweep[sn][d_lo], sweep[sn][d_hi]
        shrink = lo["arg_bytes_per_device"] / max(
            1, hi["arg_bytes_per_device"])
        sweep[sn][f"arg_shrink_{d_hi}dev"] = round(shrink, 3)
        # the point of the exercise: sharding the client axis over D
        # devices must shrink each device's resident argument bytes ~D-fold
        assert shrink > int(d_hi) / 2, \
            f"N={sn}: {d_hi}-device arg bytes shrank only {shrink:.2f}x"
        rows.append({
            "name": f"shard/sweepN{sn}/dev{d_hi}",
            "us_per_call": 0.0,
            "derived": f"arg_shrink={shrink:.2f}x "
                       f"arg_mb={hi['arg_bytes_per_device'] / 1e6:.1f} "
                       f"flops={hi['flops']:.3g}",
        })

    import jax
    report = {"tiny": tiny, "host_cores": os.cpu_count(),
              "backend": jax.default_backend(),
              "note": "fabricated host devices share the host's cores; "
                      "wall-clock measures sharding overhead, the per-"
                      "device bytes/flops measure what scales on real "
                      "meshes",
              "fixed_n": {"clients": n, "rounds": rounds_n,
                          "batches_per_round": batches, "batch": batch,
                          "seq": seq, "devices": fixed},
              "n_sweep": sweep}
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    rows.append({
        "name": "shard/json",
        "us_per_call": 0.0,
        "derived": f"written={os.path.basename(json_path)} "
                   f"devices={list(device_counts)} "
                   f"sweep_n={list(sweep_n)}",
    })
    return rows
